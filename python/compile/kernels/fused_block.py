"""Fused score-net block kernel: ``gelu(x @ W + b + m)``.

This is the score network's hot spot (one per residual block, twice per
score evaluation). Fusing the bias add, the per-sample time-modulation
``m = temb @ U`` (computed outside; XLA fuses that small matmul) and the
GELU into the matmul epilogue removes three full HBM round-trips over the
[B, N] activation that the original PyTorch sampler performs as separate
kernels.

TPU mapping (DESIGN.md §8):
  * grid tiles (bm, bn) target the 128x128 MXU systolic array; the K
    dimension is kept whole per tile (our layer widths are <= 3072 so an
    x-tile of 128xK f32 is <= 1.5 MiB, within the ~16 MiB VMEM budget
    alongside the KxbN weight tile: 3072x128x4 = 1.5 MiB).
  * VMEM footprint per grid cell: bm*K + K*bn + bm*bn + bn floats.
    For (bm, bn, K) = (128, 128, 3072): 1.5 + 1.5 + 0.0625 + 0.0005 MiB
    = ~3.1 MiB -> double-bufferable.
  * epilogue (bias+mod+GELU) runs on the VPU over the resident tile.

On CPU we lower with interpret=True (Mosaic custom-calls cannot execute
on the CPU PJRT plugin) — the interpreter inlines the kernel body as
plain HLO, so the fused structure survives into the artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, m_ref, o_ref):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jax.nn.gelu(acc + b_ref[...][None, :] + m_ref[...])


def fused_block(x, w, b, m, *, block_m: int | None = None, block_n: int = 128):
    """y = gelu(x @ w + b + m).

    x: [B, K]   activations
    w: [K, N]   weights
    b: [N]      bias
    m: [B, N]   per-sample modulation (time embedding projection)
    """
    bsz, k = x.shape
    n = w.shape[1]
    bm = block_m or min(bsz, 64)
    bn = min(block_n, n)
    assert bsz % bm == 0 and n % bn == 0, (x.shape, w.shape, bm, bn)
    grid = (bsz // bm, n // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.float32),
        interpret=True,
    )(x, w, b, m)
