"""Layer-1 Pallas kernels (always lowered with interpret=True on this
CPU-PJRT testbed; see DESIGN.md §8 for the TPU tiling they encode)."""

from compile.kernels.fused_block import fused_block
from compile.kernels.em_update import em_update
from compile.kernels.err_norm import err_norm

__all__ = ["fused_block", "em_update", "err_norm"]
