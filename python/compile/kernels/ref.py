"""Pure-jnp oracles for the Pallas kernels — the L1 correctness signal.

``python/tests/test_kernels.py`` asserts allclose between each kernel and
its oracle across a hypothesis sweep of shapes/values; the AOT artifacts
are lowered from the kernels, so this pins the served numerics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_block_ref(x, w, b, m):
    return jax.nn.gelu(x @ w + b[None, :] + m)


def em_update_ref(x, u, z, a, c):
    return x + a[:, None] * u + c[:, None] * z


def err_norm_ref(xp, xpp, xprev, eps_abs, eps_rel):
    delta = jnp.maximum(
        eps_abs[0], eps_rel[:, None] * jnp.maximum(jnp.abs(xp), jnp.abs(xprev))
    )
    r = (xp - xpp) / delta
    return jnp.sqrt(jnp.mean(r * r, axis=1))
