"""The "synthception" network: a small classifier whose penultimate
features define FID* and whose class posterior defines IS* (DESIGN.md §2).

  feat   = gelu(gelu(gelu(x W1+b1) W2+b2) W3+b3)   [B, FEAT_DIM]
  logits = feat W4 + b4                            [B, n_classes]

Trained with cross-entropy on the labelled procedural dataset, with
Gaussian input jitter so features stay informative on slightly-off
generated samples (same reason Inception-v3 works for FID: it was trained
on augmented data). Flat-vector params like the score net.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

FEAT_DIM = 64
HID = 256


@dataclasses.dataclass(frozen=True)
class FidCfg:
    dim: int
    n_classes: int


def param_shapes(cfg: FidCfg):
    return [
        ("w1", (cfg.dim, HID)),
        ("b1", (HID,)),
        ("w2", (HID, HID)),
        ("b2", (HID,)),
        ("w3", (HID, FEAT_DIM)),
        ("b3", (FEAT_DIM,)),
        ("w4", (FEAT_DIM, cfg.n_classes)),
        ("b4", (cfg.n_classes,)),
    ]


def n_params(cfg: FidCfg) -> int:
    return sum(int(np.prod(s)) for _, s in param_shapes(cfg))


def unflatten(flat, cfg: FidCfg):
    out, off = {}, 0
    for name, shape in param_shapes(cfg):
        size = int(np.prod(shape))
        out[name] = flat[off : off + size].reshape(shape)
        off += size
    return out


def init_params(seed: int, cfg: FidCfg) -> np.ndarray:
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in param_shapes(cfg):
        if name.startswith("b"):
            chunks.append(np.zeros(shape, np.float32))
        else:
            chunks.append(
                rng.normal(0, 1 / math.sqrt(shape[0]), size=shape).astype(np.float32)
            )
    return np.concatenate([c.reshape(-1) for c in chunks])


def features_logits(flat, x, cfg: FidCfg):
    """x in [0,1] (VP outputs are mapped by the caller). -> (feat, logits)."""
    p = unflatten(flat, cfg)
    h = jax.nn.gelu(x @ p["w1"] + p["b1"])
    h = jax.nn.gelu(h @ p["w2"] + p["b2"])
    feat = jax.nn.gelu(h @ p["w3"] + p["b3"])
    logits = feat @ p["w4"] + p["b4"]
    return feat, logits


FIDNETS = {
    # name -> (datasets it must discriminate, input dim)
    "fid16": (["synth-cifar"], 16 * 16 * 3),
    "fid32": (["synth-church", "synth-ffhq"], 32 * 32 * 3),
}
