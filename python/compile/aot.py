"""AOT compilation: lower every solver program to HLO *text* artifacts.

One artifact per (model variant, program, batch bucket):

  score          (theta, x[B,D], t[B])                          -> s[B,D]
  adaptive_step  (theta, x, xprev, t[B], h[B], z[B,D],
                  eps_abs[1], eps_rel[B])                       -> (x'', x', E2[B])
  em_step        (theta, x, t[B], h[B], z[B,D])                 -> x_next
  pc_step        (theta, x, t[B], h[B], z1, z2, snr[B])         -> x_next
  ddim_step      (theta, x, t[B], tn[B])        [VP only]       -> x_next
  <base>k<k>     (theta, x, t[k,B], t2[k,B], z[k,B,D]..., snr?) -> x_next
                 fused k-grid-nodes-per-dispatch variant of each
                 fixed-step kernel (em_stepk8 etc.), lowered with an
                 UNTUPLED root so the runtime can keep x device-resident
                 across dispatches; pad rows (h=0 / t_next==t) are exact
                 no-ops via a per-lane select
  adaptive_stepk<k> (theta, slab[2BD+4kB], t f64[B], h f64[B], live[B],
                  z[k,B,D], eps_abs[1], eps_rel[B], actrl f64[3]) -> slab'
                 fused k-attempts-per-dispatch Algorithm 1 fold: the
                 accept/reject test and step controller run on device in
                 f64 (actrl = [t_eps, safety, r]); the packed slab is
                 x | xprev | t_log | h_log | err_log | accept_log with
                 the [k*B] attempt logs zero on input and filled per
                 attempt, so the host folds NFE/rejections/diagnostics
                 from the downloaded log without re-running anything
  ode_drift      (theta, x, t[B])                               -> dx/dt
  denoise        (theta, x, t[B])                               -> x0_hat
  fid_features   (theta_c, x[B,D])                              -> (feat, logits)

Interchange is HLO TEXT, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published `xla` 0.1.6 crate binds) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

`adaptive_step` is the paper's Algorithm 1 step: both score evaluations,
both integrators (EM proposal x' and stochastic-improved-Euler
extrapolation x''), and the mixed-tolerance scaled-l2 error E2, fused in
one executable — accept/reject and the step-size controller stay in the
Rust coordinator. Per-sample t and h vectors implement §3.1.5.

Run: cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import fid_net, model
from compile.kernels import em_update, err_norm

SCORE_BUCKETS = (1, 16, 64)
# Power-of-two ladder up to 16: the serving engine's occupancy-aware
# scheduler migrates lanes to the smallest compiled bucket that fits the
# live batch, so low-occupancy traffic stops paying full-width steps.
# Every *serving* step program shares this ladder — adaptive_step,
# em_step, ddim_step and pc_step each back a lane-program pool behind
# the scheduler (rust coordinator/programs.rs) — and denoise shares it too
# because converged lanes are denoised at whatever width the pool
# currently runs.
STEP_BUCKETS = (1, 2, 4, 8, 16, 64)
AUX_BUCKETS = (16, 64)
FID_BUCKETS = (64,)
# k values the fused k-steps-per-dispatch variants are lowered at, for
# every fixed-step kernel and step bucket. Must mirror (or stay within)
# max_steps_per_dispatch in rust/src/solvers/spec.rs — the registry
# clamps serving k to both.
FUSED_STEPS = (4, 8)

# Fixed-step bases that get fused variants: name -> (stacked noise
# tensors, trailing per-lane snr input). The [k,B] t/t2 stacks are
# common to all three.
FUSED_BASES = {
    "em_step": (1, False),
    "pc_step": (2, True),
    "ddim_step": (0, False),
}


def fused_name(base: str, k: int) -> str:
    """Fused-variant artifact name (em_step, 8 -> "em_stepk8"); the
    naming contract is shared with solvers/spec.rs::fused_artifact."""
    return f"{base}k{k}"

# CLI-overridable (see main): CI builds a miniature artifact set with
# --step-buckets 1,2 so the artifact-gated serving tests run in minutes.
BUCKET_OVERRIDES: dict[str, tuple[int, ...]] = {}


def _buckets(kind: str, default: tuple[int, ...]) -> tuple[int, ...]:
    return BUCKET_OVERRIDES.get(kind, default)


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    # return_tuple=False lowers a bare-array root instead of a 1-tuple:
    # the fused step artifacts use it so the runtime can feed the output
    # buffer straight back in as the next dispatch's x (a PjRT tuple
    # output cannot be reused as an input without a host round-trip).
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


# --- program builders (closed over cfg/sde) -----------------------------------

def make_programs(cfg: model.ModelCfg):
    sde = cfg.sde

    def score(flat, x, t):
        return model.score(flat, x, t, cfg)

    def rdp_drift(flat, x, t):
        """Reverse-process deterministic term f(x,t) - g(t)^2 s(x,t)."""
        g2 = sde.diffusion(t) ** 2
        return sde.drift(x, t) - g2[:, None] * score(flat, x, t)

    def em_step(flat, x, t, h, z):
        # x_next = x - h*(f - g^2 s) + sqrt(h) g z       (reverse time)
        return em_update(x, rdp_drift(flat, x, t), z, -h, jnp.sqrt(h) * sde.diffusion(t))

    def adaptive_step(flat, x, xprev, t, h, z, ea, er):
        d1 = rdp_drift(flat, x, t)
        xp = em_update(x, d1, z, -h, jnp.sqrt(h) * sde.diffusion(t))
        t2 = t - h
        d2 = rdp_drift(flat, xp, t2)
        xt = em_update(x, d2, z, -h, jnp.sqrt(h) * sde.diffusion(t2))
        xpp = 0.5 * (xp + xt)  # stochastic improved Euler (Roberts 2012)
        e2 = err_norm(xp, xpp, xprev, ea, er)
        return xpp, xp, e2

    def pc_step(flat, x, t, h, z1, z2, snr):
        # predictor: reverse-diffusion (EM form); corrector: Langevin.
        # snr is per-lane (shape [B], like t and h — §3.1.5), so serving
        # lanes with different SNR targets co-batch, and a free lane with
        # h = 0, z1 = z2 = 0, snr = 0 rides through as an exact no-op.
        x1 = em_step(flat, x, t, h, z1)
        t2 = t - h
        s = score(flat, x1, t2)
        zn = jnp.sqrt(jnp.sum(z2 * z2, axis=1))
        sn = jnp.sqrt(jnp.sum(s * s, axis=1)) + 1e-20
        alpha = 2.0 * (snr * zn / sn) ** 2
        return em_update(x1, s, z2, alpha, jnp.sqrt(2.0 * alpha))

    def ddim_step(flat, x, t, tn):
        a_t, a_n = sde.alpha(t), sde.alpha(tn)
        std_t, std_n = sde.marginal_std(t), sde.marginal_std(tn)
        eps = model.apply_eps(flat, x, t, cfg)
        x0 = (x - std_t[:, None] * eps) / a_t[:, None]
        return a_n[:, None] * x0 + std_n[:, None] * eps

    def ode_drift(flat, x, t):
        g2 = sde.diffusion(t) ** 2
        return sde.drift(x, t) - 0.5 * g2[:, None] * score(flat, x, t)

    def denoise(flat, x, t):
        # Tweedie (paper App. D, corrected): x0 = (x + Var[x(t)|x0] s) / mean_coef
        var = sde.tweedie_var(t)
        x0 = x + var[:, None] * score(flat, x, t)
        return x0 / sde.mean_coef(t)[:, None]

    return {
        "score": score,
        "adaptive_step": adaptive_step,
        "em_step": em_step,
        "pc_step": pc_step,
        "ddim_step": ddim_step,
        "ode_drift": ode_drift,
        "denoise": denoise,
    }


def _fused_driver(step_fn, is_noop):
    """k-grid-nodes-per-dispatch wrapper around a single-step kernel.

    t/t2 and the noise tensors arrive stacked [k, ...]; iteration j runs
    the single-step body on row j and then selects the old x for lanes
    whose row is a no-op pad (a lane with fewer than k nodes left rides
    the tail with h=0 / t_next==t and draws no noise). The select makes
    pad rows bit-exact even for kernels whose no-op arithmetic is only
    approximately the identity (ddim divides and re-multiplies by
    alpha(t)); live rows run arithmetic identical to the k=1 kernel, so
    fused outputs match k sequential single-step dispatches bitwise.
    """

    def run(flat, x, t, t2, *rest):
        def body(j, xc):
            xn = step_fn(flat, xc, t[j], t2[j], *[r[j] if r.ndim == 3 else r for r in rest])
            return jnp.where(is_noop(t[j], t2[j])[:, None], xc, xn)

        return jax.lax.fori_loop(0, t.shape[0], body, x)

    return run


def make_fused_programs(cfg: model.ModelCfg):
    """Fused k-step drivers, one per FUSED_BASES entry. Each driver is
    k-agnostic (k comes from the stacked input shapes), so one function
    lowers at every (k, bucket) pair."""
    progs = make_programs(cfg)

    def noop_h(t, h):
        return h == 0.0

    def noop_tn(t, tn):
        return tn == t

    return {
        "em_step": _fused_driver(progs["em_step"], noop_h),
        "pc_step": _fused_driver(progs["pc_step"], noop_h),
        "ddim_step": _fused_driver(progs["ddim_step"], noop_tn),
    }


def make_adaptive_fused(cfg: model.ModelCfg):
    """Fused k-attempts-per-dispatch driver for Algorithm 1.

    Unlike the fixed-step drivers, the loop body is the *whole* adaptive
    step: both score evals, the mixed-norm error test, accept/reject and
    the step-size controller. The controller state (t, h) stays f64 on
    device — the same precision the Rust host controller evolves it at —
    so attempt j+1 sees bit-identical (t, h) to what k=1 would have
    computed on the host after attempt j. The f32 casts fed to the score
    net are the same round-to-nearest casts the host performs per
    dispatch, and x/xprev updates are per-lane selects of the f32 kernel
    outputs, so lane state is bitwise equal to k sequential k=1
    dispatches. Lanes that converge mid-dispatch (or arrive dead via
    live = 0) are select-masked no-ops for the remaining attempts.

    The state rides a single packed f32 slab (the artifact is lowered
    untupled so the root buffer feeds straight back in as the next
    dispatch's input): x | xprev | t_log | h_log | err_log | accept_log.
    The [k*B] logs record, per attempt, the f32 (t, h) the kernel ran
    at, the f32 error norm, and the accept bit — everything the host
    needs to replay the f64 controller, bill NFE/rejections and feed the
    diagnostics bins without re-running the step. Dead-lane log entries
    are zeroed. actrl = [t_eps, safety, r] in f64.
    """
    progs = make_programs(cfg)
    astep = progs["adaptive_step"]
    d = cfg.dim
    f32, f64 = jnp.float32, jnp.float64

    def run(flat, slab, t, h, live, z, ea, er, actrl):
        k, b = z.shape[0], z.shape[1]
        x = slab[: b * d].reshape(b, d)
        xprev = slab[b * d : 2 * b * d].reshape(b, d)
        t_eps, safety, r = actrl[0], actrl[1], actrl[2]
        zero_log = jnp.zeros((k, b), f32)

        def body(j, carry):
            x, xprev, t, h, alive, tl, hl, el, al = carry
            # pre-step clamp, exactly the host's h.min(t - t_eps).max(0)
            hc = jnp.maximum(jnp.minimum(h, t - t_eps), 0.0)
            t32 = t.astype(f32)
            h32 = hc.astype(f32)
            xpp, xp, e2 = astep(flat, x, xprev, t32, h32, z[j], ea, er)
            err = e2.astype(f64)
            acc = alive & (err <= 1.0)
            xn = jnp.where(acc[:, None], xpp, x)
            xpn = jnp.where(acc[:, None], xp, xprev)
            tn = jnp.where(acc, t - hc, t)
            conv = acc & (tn <= t_eps + 1e-12)
            # h' = (h * safety * err^-r) clamped to the remaining span,
            # in f64 like the host controller (incl. the 1e-12 floor)
            grow = safety * jnp.maximum(err, 1e-12) ** (-r)
            hn = jnp.where(
                alive, jnp.minimum(hc * grow, jnp.maximum(tn - t_eps, 0.0)), h
            )
            tl = tl.at[j].set(jnp.where(alive, t32, 0.0))
            hl = hl.at[j].set(jnp.where(alive, h32, 0.0))
            el = el.at[j].set(jnp.where(alive, e2, 0.0))
            al = al.at[j].set(acc.astype(f32))
            return (xn, xpn, tn, hn, alive & ~conv, tl, hl, el, al)

        init = (x, xprev, t, h, live > 0.0, zero_log, zero_log, zero_log, zero_log)
        x, xprev, _, _, _, tl, hl, el, al = jax.lax.fori_loop(0, k, body, init)
        return jnp.concatenate([
            x.reshape(-1), xprev.reshape(-1),
            tl.reshape(-1), hl.reshape(-1), el.reshape(-1), al.reshape(-1),
        ])

    return run


def program_specs(cfg: model.ModelCfg, n_theta: int):
    """(program -> (buckets, arg-spec builder)). Shapes are the runtime ABI."""
    d = cfg.dim

    def f32(*shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    def f64(*shape):
        return jax.ShapeDtypeStruct(shape, jnp.float64)

    def args(b, program):
        theta = f32(n_theta)
        if program == "score" or program == "ode_drift" or program == "denoise":
            return (theta, f32(b, d), f32(b))
        if program == "adaptive_step":
            return (theta, f32(b, d), f32(b, d), f32(b), f32(b), f32(b, d),
                    f32(1), f32(b))
        if program == "em_step":
            return (theta, f32(b, d), f32(b), f32(b), f32(b, d))
        if program == "pc_step":
            return (theta, f32(b, d), f32(b), f32(b), f32(b, d), f32(b, d), f32(b))
        if program == "ddim_step":
            return (theta, f32(b, d), f32(b), f32(b))
        base, _, kk = program.rpartition("k")
        if base == "adaptive_step" and kk.isdigit():
            # packed slab (x | xprev | 4 [k*B] attempt logs) + f64
            # controller state/constants (actrl = [t_eps, safety, r])
            k = int(kk)
            return (theta, f32(2 * b * d + 4 * k * b), f64(b), f64(b), f32(b),
                    f32(k, b, d), f32(1), f32(b), f64(3))
        if base in FUSED_BASES and kk.isdigit():
            k = int(kk)
            nz, snr = FUSED_BASES[base]
            sig = (theta, f32(b, d), f32(k, b), f32(k, b))
            sig += tuple(f32(k, b, d) for _ in range(nz))
            return sig + ((f32(b),) if snr else ())
        raise KeyError(program)

    score_b = _buckets("score", SCORE_BUCKETS)
    step_b = _buckets("step", STEP_BUCKETS)
    aux_b = _buckets("aux", AUX_BUCKETS)
    buckets = {
        "score": score_b,
        "adaptive_step": step_b,
        "em_step": step_b,
        # pc_step and ddim_step back serving lane pools (ddim VP only),
        # so they ride the step ladder like adaptive_step/em_step
        "pc_step": step_b,
        "ddim_step": step_b,
        "ode_drift": aux_b,
        # denoise runs at whatever bucket the solver/engine uses
        "denoise": step_b,
    }
    return buckets, args


def lower_variant(name: str, art_dir: str, manifest: dict):
    with open(os.path.join(art_dir, "params", f"{name}.meta.json")) as f:
        meta = json.load(f)
    cfg = model.ModelCfg(
        dim=meta["dim"], hidden=meta["hidden"], blocks=meta["blocks"],
        sde_kind=meta["sde_kind"], sigma_max=meta["sigma_max"],
    )
    n_theta = model.n_params(cfg)
    assert n_theta == meta["n_params"], (name, n_theta, meta["n_params"])
    programs = make_programs(cfg)
    buckets, args = program_specs(cfg, n_theta)
    vdir = os.path.join(art_dir, name)
    os.makedirs(vdir, exist_ok=True)
    entries = []
    for program, fn in programs.items():
        if program == "ddim_step" and cfg.sde_kind != "vp":
            continue
        for b in buckets[program]:
            spec = args(b, program)
            text = to_hlo_text(jax.jit(fn).lower(*spec))
            fname = f"{program}_b{b}.hlo.txt"
            with open(os.path.join(vdir, fname), "w") as f:
                f.write(text)
            entries.append({
                "program": program,
                "bucket": b,
                "file": f"{name}/{fname}",
                "inputs": [list(s.shape) for s in spec],
                "n_outputs": 3 if program == "adaptive_step" else 1,
            })
            print(f"[aot] {name}/{fname} ({len(text)//1024} KiB)", flush=True)
    # fused k-step variants ride the same step-bucket ladder; their
    # manifest entries carry steps_per_dispatch + untupled so the
    # runtime dispatches them through the device-resident path
    fused = make_fused_programs(cfg)
    for base, fn in fused.items():
        if base == "ddim_step" and cfg.sde_kind != "vp":
            continue
        for k in _buckets("fused", FUSED_STEPS):
            program = fused_name(base, k)
            for b in buckets[base]:
                spec = args(b, program)
                text = to_hlo_text(jax.jit(fn).lower(*spec), return_tuple=False)
                fname = f"{program}_b{b}.hlo.txt"
                with open(os.path.join(vdir, fname), "w") as f:
                    f.write(text)
                entries.append({
                    "program": program,
                    "bucket": b,
                    "file": f"{name}/{fname}",
                    "inputs": [list(s.shape) for s in spec],
                    "n_outputs": 1,
                    "steps_per_dispatch": k,
                    "untupled": True,
                })
                print(f"[aot] {name}/{fname} ({len(text)//1024} KiB)", flush=True)
    # fused adaptive variants: the accept/reject fold runs the step-size
    # controller on device in f64, so the lowering is scoped under x64
    # (Python float literals stay weakly typed — the score net and the
    # pallas kernels keep their f32 internals)
    afold = make_adaptive_fused(cfg)
    for k in _buckets("fused", FUSED_STEPS):
        program = fused_name("adaptive_step", k)
        for b in buckets["adaptive_step"]:
            spec = args(b, program)
            with jax.experimental.enable_x64():
                text = to_hlo_text(jax.jit(afold).lower(*spec), return_tuple=False)
            fname = f"{program}_b{b}.hlo.txt"
            with open(os.path.join(vdir, fname), "w") as f:
                f.write(text)
            entries.append({
                "program": program,
                "bucket": b,
                "file": f"{name}/{fname}",
                "inputs": [list(s.shape) for s in spec],
                "n_outputs": 1,
                "steps_per_dispatch": k,
                "untupled": True,
            })
            print(f"[aot] {name}/{fname} ({len(text)//1024} KiB)", flush=True)
    manifest["variants"][name] = {"meta": meta, "programs": entries}


def lower_fidnet(name: str, art_dir: str, manifest: dict):
    with open(os.path.join(art_dir, "params", f"{name}.meta.json")) as f:
        meta = json.load(f)
    cfg = fid_net.FidCfg(dim=meta["dim"], n_classes=meta["n_classes"])
    n_theta = fid_net.n_params(cfg)

    def features(flat, x):
        return fid_net.features_logits(flat, x, cfg)

    vdir = os.path.join(art_dir, name)
    os.makedirs(vdir, exist_ok=True)
    entries = []
    for b in _buckets("fid", FID_BUCKETS):
        spec = (
            jax.ShapeDtypeStruct((n_theta,), jnp.float32),
            jax.ShapeDtypeStruct((b, cfg.dim), jnp.float32),
        )
        text = to_hlo_text(jax.jit(features).lower(*spec))
        fname = f"fid_features_b{b}.hlo.txt"
        with open(os.path.join(vdir, fname), "w") as f:
            f.write(text)
        entries.append({
            "program": "fid_features", "bucket": b, "file": f"{name}/{fname}",
            "inputs": [list(s.shape) for s in spec], "n_outputs": 2,
        })
        print(f"[aot] {name}/{fname} ({len(text)//1024} KiB)", flush=True)
    manifest["fidnets"][name] = {"meta": meta, "programs": entries}


def _bucket_list(spec: str) -> tuple[int, ...]:
    return tuple(sorted({int(p) for p in spec.split(",") if p.strip()}))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--variant", default=None, help="limit to one variant")
    for kind, default in [
        ("score", SCORE_BUCKETS),
        ("step", STEP_BUCKETS),
        ("aux", AUX_BUCKETS),
        ("fid", FID_BUCKETS),
    ]:
        ap.add_argument(
            f"--{kind}-buckets",
            default=None,
            help=f"comma-separated bucket override (default {default}); "
            "e.g. --step-buckets 1,2 for a miniature CI artifact set",
        )
    ap.add_argument(
        "--fused-steps",
        default=None,
        help="comma-separated k values to lower fused k-steps-per-dispatch "
        f"step variants at (default {FUSED_STEPS}; each k must be >= 2); "
        "an empty string disables fused lowering",
    )
    args = ap.parse_args()
    for kind in ("score", "step", "aux", "fid"):
        spec = getattr(args, f"{kind}_buckets")
        if spec is not None:
            BUCKET_OVERRIDES[kind] = _bucket_list(spec)
    if args.fused_steps is not None:
        ks = _bucket_list(args.fused_steps)
        if any(k < 2 for k in ks):
            ap.error("--fused-steps values must be >= 2")
        BUCKET_OVERRIDES["fused"] = ks
    art = args.out
    manifest = {"variants": {}, "fidnets": {}}
    mpath = os.path.join(art, "manifest.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            manifest = json.load(f)
    variants = [args.variant] if args.variant else list(model.VARIANTS)
    fidnets = [] if args.variant else list(fid_net.FIDNETS)
    if args.variant in fid_net.FIDNETS:
        variants, fidnets = [], [args.variant]
    for v in variants:
        lower_variant(v, art, manifest)
    for f_ in fidnets:
        lower_fidnet(f_, art, manifest)
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest -> {mpath}")


if __name__ == "__main__":
    main()
