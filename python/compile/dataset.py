"""Procedural labelled image datasets (DESIGN.md §2 substitutions).

Stand-ins for the paper's evaluation corpora, deterministic given a seed:

  synth-cifar  — 16x16x3, 6 shape/texture classes (CIFAR-10 stand-in)
  synth-church — 32x32x3, tower/roof-line scenes, 4 classes (LSUN-Church)
  synth-ffhq   — 32x32x3, radial face-like compositions, 4 classes (FFHQ)

Images are float32 in [0, 1], returned flattened [N, H*W*3] (HWC order).
Labels feed the synthception classifier used for FID*/IS*.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    h: int
    w: int
    c: int = 3
    n_classes: int = 6
    seed: int = 0

    @property
    def dim(self) -> int:
        return self.h * self.w * self.c


SPECS = {
    "synth-cifar": DatasetSpec("synth-cifar", 16, 16, n_classes=6, seed=1234),
    "synth-church": DatasetSpec("synth-church", 32, 32, n_classes=4, seed=2345),
    "synth-ffhq": DatasetSpec("synth-ffhq", 32, 32, n_classes=4, seed=3456),
}


def _grid(h, w):
    yy, xx = np.meshgrid(np.linspace(0, 1, h), np.linspace(0, 1, w), indexing="ij")
    return yy, xx


def _bg(rng, h, w):
    """Smooth two-colour gradient background."""
    yy, xx = _grid(h, w)
    c0 = rng.uniform(0.05, 0.95, size=3)
    c1 = rng.uniform(0.05, 0.95, size=3)
    ang = rng.uniform(0, 2 * np.pi)
    ramp = (np.cos(ang) * xx + np.sin(ang) * yy + 1) / 2
    return c0[None, None] * ramp[..., None] + c1[None, None] * (1 - ramp[..., None])


def _cifar_img(rng, spec, label):
    h, w = spec.h, spec.w
    img = _bg(rng, h, w)
    yy, xx = _grid(h, w)
    cy, cx = rng.uniform(0.3, 0.7, size=2)
    r = rng.uniform(0.15, 0.35)
    col = rng.uniform(0.0, 1.0, size=3)
    if label == 0:  # circle
        mask = (yy - cy) ** 2 + (xx - cx) ** 2 < r**2
    elif label == 1:  # square
        mask = (np.abs(yy - cy) < r) & (np.abs(xx - cx) < r)
    elif label == 2:  # cross
        t = r * 0.45
        mask = ((np.abs(yy - cy) < t) & (np.abs(xx - cx) < r)) | (
            (np.abs(xx - cx) < t) & (np.abs(yy - cy) < r)
        )
    elif label == 3:  # horizontal stripes
        f = rng.integers(2, 5)
        mask = (np.sin(yy * np.pi * 2 * f + rng.uniform(0, np.pi)) > 0.2)
    elif label == 4:  # vertical stripes
        f = rng.integers(2, 5)
        mask = (np.sin(xx * np.pi * 2 * f + rng.uniform(0, np.pi)) > 0.2)
    else:  # checker
        f = rng.integers(2, 4)
        mask = (np.sin(yy * np.pi * 2 * f) * np.sin(xx * np.pi * 2 * f)) > 0
    img = np.where(mask[..., None], col[None, None], img)
    return img


def _church_img(rng, spec, label):
    """label = number of towers - 1 (1..4 towers)."""
    h, w = spec.h, spec.w
    img = _bg(rng, h, w)  # sky
    yy, xx = _grid(h, w)
    ground = rng.uniform(0.55, 0.8)
    gcol = rng.uniform(0.1, 0.4, size=3)
    img = np.where((yy > ground)[..., None], gcol[None, None], img)
    n_towers = label + 1
    for k in range(n_towers):
        cx = (k + 0.5 + rng.uniform(-0.15, 0.15)) / n_towers
        tw = rng.uniform(0.05, 0.12)
        top = rng.uniform(0.15, 0.45)
        tcol = rng.uniform(0.2, 0.9, size=3)
        body = (np.abs(xx - cx) < tw) & (yy > top) & (yy <= ground + 0.1)
        img = np.where(body[..., None], tcol[None, None], img)
        # spire: triangle above the body
        spire = (np.abs(xx - cx) < tw * (1 - (top - yy) / 0.12)) & (yy <= top) & (
            yy > top - 0.12
        )
        img = np.where(spire[..., None], (tcol * 0.7)[None, None], img)
    return img


def _ffhq_img(rng, spec, label):
    """Face-like compositions; label = skin/hair combo class."""
    h, w = spec.h, spec.w
    img = _bg(rng, h, w)
    yy, xx = _grid(h, w)
    skin = np.array(
        [[0.95, 0.8, 0.7], [0.8, 0.6, 0.45], [0.6, 0.45, 0.35], [0.45, 0.3, 0.25]]
    )[label] * rng.uniform(0.9, 1.1)
    cy, cx = 0.5 + rng.uniform(-0.06, 0.06, size=2)
    ry, rx = rng.uniform(0.28, 0.38), rng.uniform(0.22, 0.3)
    face = ((yy - cy) / ry) ** 2 + ((xx - cx) / rx) ** 2 < 1
    img = np.where(face[..., None], skin[None, None], img)
    # hair cap
    hcol = rng.uniform(0.05, 0.6, size=3)
    hair = (((yy - cy) / ry) ** 2 + ((xx - cx) / rx) ** 2 < 1.25) & (yy < cy - 0.12)
    img = np.where(hair[..., None], hcol[None, None], img)
    # eyes
    for sx in (-1, 1):
        ex, ey = cx + sx * rx * 0.45, cy - ry * 0.15
        eye = (yy - ey) ** 2 + (xx - ex) ** 2 < rng.uniform(0.015, 0.03) ** 2 * 4
        img = np.where(eye[..., None], np.array([0.05, 0.05, 0.1])[None, None], img)
    # mouth
    mouth = (np.abs(yy - (cy + ry * 0.45)) < 0.025) & (np.abs(xx - cx) < rx * 0.4)
    img = np.where(mouth[..., None], np.array([0.6, 0.15, 0.15])[None, None], img)
    return img


_MAKERS = {
    "synth-cifar": _cifar_img,
    "synth-church": _church_img,
    "synth-ffhq": _ffhq_img,
}


def _blur(img):
    """Two passes of a separable [1,2,1]/4 kernel (reflect padding).
    Low-pass filtering keeps the shapes recognisable while concentrating
    the distribution on a smooth manifold the small score nets can learn
    within the build-time training budget (DESIGN.md §2)."""
    k = np.array([0.25, 0.5, 0.25])
    for _ in range(2):
        p = np.pad(img, ((1, 1), (0, 0), (0, 0)), mode="edge")
        img = k[0] * p[:-2] + k[1] * p[1:-1] + k[2] * p[2:]
        p = np.pad(img, ((0, 0), (1, 1), (0, 0)), mode="edge")
        img = k[0] * p[:, :-2] + k[1] * p[:, 1:-1] + k[2] * p[:, 2:]
    return img


def generate(name: str, n: int, seed_offset: int = 0):
    """Return (images [n, dim] float32 in [0,1], labels [n] int32)."""
    spec = SPECS[name]
    rng = np.random.default_rng(spec.seed + seed_offset)
    labels = rng.integers(0, spec.n_classes, size=n)
    maker = _MAKERS[name]
    out = np.empty((n, spec.dim), dtype=np.float32)
    for i in range(n):
        img = _blur(maker(rng, spec, int(labels[i])))
        # mild photometric noise so the data manifold has volume
        img = np.clip(img + rng.normal(0, 0.01, size=img.shape), 0.0, 1.0)
        out[i] = img.astype(np.float32).reshape(-1)
    return out, labels.astype(np.int32)


def max_pairwise_distance(x: np.ndarray, subsample: int = 512) -> float:
    """sigma_max heuristic (paper §2.2): max Euclidean distance between
    dataset samples, estimated on a subsample."""
    n = min(subsample, x.shape[0])
    xs = x[:n]
    sq = np.sum(xs**2, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2 * xs @ xs.T
    return float(np.sqrt(max(d2.max(), 0.0)))
