"""Layer-2: the score network, written in JAX over the L1 Pallas kernels.

Architecture (time-conditioned residual MLP, a CPU-scale restatement of
NCSN++/DDPM++ from Song et al. 2020a):

  temb = gelu(fourier(t) @ Wt + bt)                        [B, H]
  h    = x @ Win + bin                                     [B, H]
  for each block l:
      inner = fused_block(h, W1_l, b1_l, temb @ U_l)       (Pallas, L1)
      h     = h + inner @ W2_l + b2_l                      (residual)
  eps  = eps_gauss(x, t) + h @ Wout + bout                 [B, D]
  score(x, t) = -eps / marginal_std(t)

where eps_gauss is the closed-form posterior noise under a Gaussian data
prior N(mu0, diag(v0)) fitted to the training set:

  eps_gauss(x, t) = std(t) (x - alpha(t) mu0) / (alpha(t)^2 v0 + std(t)^2)

This baseline is *exact* at t -> 1 (where the marginal is the prior) and
removes the rank bottleneck of predicting D-dim noise through an H < D
hidden layer — the network only learns the non-Gaussian correction.
Without it the reverse VP drift under-cancels and trajectories blow up
by exp(0.5 int beta) ~ 150x (measured; see DESIGN.md §Model).

Parameters live in ONE flat f32 vector. The Rust runtime uploads that
vector once per model as a PJRT buffer and feeds it as the first argument
of every artifact — weights are never baked into HLO (keeps artifact text
small and lets one compiled program serve retrained weights).

Variants (paper Table 1): base = 4 blocks, deep = 8 blocks; hidden width
256 for 16x16 data, 384 for 32x32 (all multiples of the 128 MXU lane).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import fused_block
from compile import sde as sde_mod


TEMB_DIM = 128  # fourier feature count (half sin, half cos)


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    dim: int            # flattened data dim (H*W*3)
    hidden: int
    blocks: int
    sde_kind: str       # "ve" | "vp"
    sigma_max: float = 50.0  # VE only; dataset max pairwise distance

    @property
    def sde(self):
        return sde_mod.make_sde(self.sde_kind, self.sigma_max)


def param_shapes(cfg: ModelCfg):
    """Ordered (name, shape) list — the single source of truth for the
    flat layout. Mirrored nowhere: Rust only ever sees the flat vector."""
    h, d = cfg.hidden, cfg.dim
    shapes = [
        ("temb_w", (TEMB_DIM, h)),
        ("temb_b", (h,)),
        ("in_w", (d, h)),
        ("in_b", (h,)),
    ]
    for l in range(cfg.blocks):
        shapes += [
            (f"blk{l}_w1", (h, h)),
            (f"blk{l}_b1", (h,)),
            (f"blk{l}_u", (h, h)),
            (f"blk{l}_w2", (h, h)),
            (f"blk{l}_b2", (h,)),
        ]
    shapes += [("out_w", (h, d)), ("out_b", (d,))]
    # Gaussian-prior baseline stats (frozen via stop_gradient in apply)
    shapes += [("mu0", (d,)), ("v0", (d,))]
    return shapes


def n_params(cfg: ModelCfg) -> int:
    return sum(int(np.prod(s)) for _, s in param_shapes(cfg))


def unflatten(flat, cfg: ModelCfg):
    out, off = {}, 0
    for name, shape in param_shapes(cfg):
        size = int(np.prod(shape))
        out[name] = flat[off : off + size].reshape(shape)
        off += size
    return out


def init_params(
    seed: int, cfg: ModelCfg, mu0: np.ndarray | None = None, v0: np.ndarray | None = None
) -> np.ndarray:
    """LeCun-normal weights, zero biases, zeroed residual-out projections
    (standard trick so the net starts as identity + input proj). mu0/v0
    are the dataset mean/variance in the process data range; defaults
    (0, 1) make the baseline the VP prior."""
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in param_shapes(cfg):
        if name == "mu0":
            chunks.append(
                (mu0 if mu0 is not None else np.zeros(shape)).astype(np.float32)
            )
        elif name == "v0":
            chunks.append(
                (v0 if v0 is not None else np.ones(shape)).astype(np.float32)
            )
        elif "_b" in name:  # temb_b, in_b, out_b, blk*_b1, blk*_b2
            chunks.append(np.zeros(shape, np.float32))
        elif "_w2" in name or name == "out_w":
            # residual branches + output head start dead: the model begins
            # as the exact Gaussian-prior score and only learns corrections
            chunks.append(np.zeros(shape, np.float32))
        else:
            fan_in = shape[0]
            chunks.append(
                rng.normal(0.0, 1.0 / math.sqrt(fan_in), size=shape).astype(np.float32)
            )
    return np.concatenate([c.reshape(-1) for c in chunks])


def eps_gauss(x, t, cfg: ModelCfg, mu0, v0):
    """Closed-form E[eps | x_t] under a Gaussian data prior N(mu0, v0)."""
    s = cfg.sde
    alpha = s.mean_coef(t)[:, None]
    std = s.marginal_std(t)[:, None]
    mu0 = jax.lax.stop_gradient(mu0)
    v0 = jax.lax.stop_gradient(v0)
    return std * (x - alpha * mu0[None, :]) / (alpha**2 * v0[None, :] + std**2)


def residual_scale(t, cfg: ModelCfg, v0):
    """Bayes residual-std fraction sqrt(a^2 v / (a^2 v + s^2)) — the most
    any correction on top of eps_gauss can explain. Scaling the network
    output by it pins eps to the (exact) baseline at t -> 1 and gives the
    correction a well-conditioned O(1) target at structure-forming t.
    Without it the randomly-initialised output head injects large-t score
    error that visibly corrupts early reverse steps (DESIGN.md §10)."""
    s = cfg.sde
    a = s.mean_coef(t)[:, None]
    std = s.marginal_std(t)[:, None]
    vbar = jax.lax.stop_gradient(jnp.mean(v0))
    return jnp.sqrt(a * a * vbar / (a * a * vbar + std * std))


def fourier_features(t):
    """[B] -> [B, TEMB_DIM]; log-spaced frequencies covering t in [0,1]."""
    half = TEMB_DIM // 2
    # dtype pinned so the features stay f32 even when a caller traces
    # under enable_x64 (the fused adaptive fold's f64 step controller)
    freqs = jnp.exp(jnp.linspace(math.log(0.5), math.log(256.0), half, dtype=jnp.float32))
    ang = 2.0 * math.pi * t[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def apply_eps(flat, x, t, cfg: ModelCfg):
    """Noise prediction eps_theta(x, t): [B,D],[B] -> [B,D]."""
    p = unflatten(flat, cfg)
    temb = jax.nn.gelu(fourier_features(t) @ p["temb_w"] + p["temb_b"])
    h = x @ p["in_w"] + p["in_b"]
    for l in range(cfg.blocks):
        mod = temb @ p[f"blk{l}_u"]
        inner = fused_block(h, p[f"blk{l}_w1"], p[f"blk{l}_b1"], mod)
        h = h + inner @ p[f"blk{l}_w2"] + p[f"blk{l}_b2"]
    w = residual_scale(t, cfg, p["v0"])
    return eps_gauss(x, t, cfg, p["mu0"], p["v0"]) + w * (h @ p["out_w"] + p["out_b"])


def apply_eps_ref(flat, x, t, cfg: ModelCfg):
    """Pure-jnp twin of apply_eps (kernel replaced by its oracle) — used by
    training (fast jit) and as the L2 correctness reference in tests."""
    from compile.kernels.ref import fused_block_ref

    p = unflatten(flat, cfg)
    temb = jax.nn.gelu(fourier_features(t) @ p["temb_w"] + p["temb_b"])
    h = x @ p["in_w"] + p["in_b"]
    for l in range(cfg.blocks):
        mod = temb @ p[f"blk{l}_u"]
        inner = fused_block_ref(h, p[f"blk{l}_w1"], p[f"blk{l}_b1"], mod)
        h = h + inner @ p[f"blk{l}_w2"] + p[f"blk{l}_b2"]
    w = residual_scale(t, cfg, p["v0"])
    return eps_gauss(x, t, cfg, p["mu0"], p["v0"]) + w * (h @ p["out_w"] + p["out_b"])


def score(flat, x, t, cfg: ModelCfg, *, use_kernel: bool = True):
    """s_theta(x,t) = -eps / std(t) — the quantity every solver consumes."""
    fn = apply_eps if use_kernel else apply_eps_ref
    eps = fn(flat, x, t, cfg)
    std = cfg.sde.marginal_std(t)
    return -eps / std[:, None]


# --- variant registry --------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Variant:
    name: str
    dataset: str
    sde_kind: str
    blocks: int
    hidden: int
    train_steps: int
    batch: int = 128
    lr: float = 2e-3


VARIANTS = {
    "vp": Variant("vp", "synth-cifar", "vp", 4, 256, 800),
    "vp_deep": Variant("vp_deep", "synth-cifar", "vp", 8, 256, 250),
    "ve": Variant("ve", "synth-cifar", "ve", 4, 256, 350),
    "ve_deep": Variant("ve_deep", "synth-cifar", "ve", 8, 256, 250),
    "ve_church": Variant("ve_church", "synth-church", "ve", 6, 384, 250),
    "ve_ffhq": Variant("ve_ffhq", "synth-ffhq", "ve", 6, 384, 250),
}
